"""Serving layer: GED verification service correctness + LM generation."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.exact.search import ged as exact_ged
from repro.data.graphs import perturb, random_graph
from repro.models.config import reduced
from repro.models.params import init_params
from repro.serving import GedRequest, GedVerificationService, generate


@pytest.fixture(scope="module")
def request_set():
    rng = np.random.default_rng(7)
    reqs, truths = [], []
    for _ in range(24):
        q = random_graph(rng, int(rng.integers(6, 11)))
        g = perturb(rng, q, int(rng.integers(1, 6)))
        true_ged = exact_ged(q, g, bound="BMa").ged
        tau = float(rng.integers(1, 7))
        reqs.append(GedRequest(q, g, tau))
        truths.append(true_ged)
    return reqs, truths


def test_verification_matches_exact(request_set):
    reqs, truths = request_set
    svc = GedVerificationService(batch_size=8, slots=16)
    results = svc.verify(reqs)
    assert len(results) == len(reqs)
    for r, req, t in zip(results, reqs, truths):
        assert r.certified
        assert r.similar == (t <= req.tau), (t, req.tau, r)
    assert svc.stats["pairs"] == len(reqs)


def test_computation_matches_exact(request_set):
    reqs, truths = request_set
    svc = GedVerificationService(batch_size=8, slots=16)
    results = svc.compute([(r.q, r.g) for r in reqs[:10]])
    for r, t in zip(results, truths[:10]):
        assert r.certified and r.ged == pytest.approx(t), (r.ged, t)


def test_escalation_path_used_for_hard_pairs():
    """Tiny first-rung budget forces escalation; answers stay exact."""
    rng = np.random.default_rng(11)
    reqs, truths = [], []
    for _ in range(6):
        q = random_graph(rng, 10, density=0.35)
        g = perturb(rng, q, 6)
        truths.append(exact_ged(q, g, bound="BMa").ged)
        reqs.append(GedRequest(q, g, tau=4.0))
    svc = GedVerificationService(batch_size=6, slots=16)
    svc.scheduler.rungs = ((8, 2, 4),)      # absurdly small engine budget
    results = svc.verify(reqs)
    assert svc.stats["escalated"] + svc.stats["host_solved"] > 0
    for r, req, t in zip(results, reqs, truths):
        assert r.certified and r.similar == (t <= req.tau)


def test_lm_generate_runs():
    cfg = reduced(get_arch("qwen3-8b"))
    cfg = dataclasses.replace(cfg, remat="none", compute_dtype="float32")
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = generate(params, prompt, cfg, max_new=4, impl="naive")
    assert out.shape == (2, 4)
    assert np.all((out >= 0) & (out < cfg.vocab))


def test_lm_generate_ssm_runs():
    cfg = reduced(get_arch("rwkv6-3b"))
    cfg = dataclasses.replace(cfg, remat="none", compute_dtype="float32")
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(1, 8)).astype(np.int32)
    out = generate(params, prompt, cfg, max_new=4, impl="naive")
    assert out.shape == (1, 4)
