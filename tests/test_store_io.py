"""Durable GraphStore + shared result cache: save/open parity, every
injected crash/corruption mode, journal/compaction round trips, the
cross-process cache tier, and the 8-device mmap-open subprocess check.

The contract under test (``docs/persistence.md``): a persistence failure
may cost a rebuild and must emit a warning, but it must *never* produce
a wrong answer — and a clean warm open must not redo ingest work.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import ged
from repro.data.graphs import perturb, random_graph
from repro.ged.results import GedOutcome
from repro.store_io import (CorruptStoreError, SchemaVersionError,
                            SharedResultCache, StoreIOError)
from repro.store_io.graphstore_io import MANIFEST_NAME

STORE_OPTS = dict(pool=256, expand=4, max_iters=256, batch_size=8)


def _corpus(seed, count, nmin=3, nmax=7, planted=2):
    rng = np.random.default_rng(seed)
    graphs = [random_graph(rng, int(rng.integers(nmin, nmax + 1)),
                           density=0.4, n_vlabels=3, n_elabels=2)
              for _ in range(count)]
    for _ in range(planted):
        graphs.append(perturb(rng, graphs[0], int(rng.integers(1, 3)),
                              n_vlabels=3, n_elabels=2))
    return graphs


def _hits(hs):
    return [(h.graph_id, h.ged, h.lower_bound, h.upper_bound, h.similar,
             h.certified, h.stage) for h in hs]


def _answers(store, queries, tau=3.0, k=4):
    return ([_hits(store.range_search(q, tau)) for q in queries]
            + [_hits(store.top_k(q, k)) for q in queries])


def _segment(store_dir, name):
    """Path of segment file ``name`` inside the current generation."""
    gens = sorted(d for d in os.listdir(store_dir) if d.startswith("seg-"))
    assert gens, store_dir
    return os.path.join(store_dir, gens[-1], name)


# ---------------------------------------------------- save/open parity

@pytest.mark.parametrize("index", ["auto", None])
def test_save_open_parity_and_no_repack(tmp_path, index):
    """A reopened store answers range and top-k queries bit-identically
    to the fresh ingest — without re-packing features or re-sketching."""
    corpus = _corpus(0, 12)
    queries = [corpus[0], corpus[3],
               perturb(np.random.default_rng(5), corpus[1], 1,
                       n_vlabels=3, n_elabels=2)]
    fresh = ged.GraphStore(corpus, index=index, **STORE_OPTS)
    want = _answers(fresh, queries)
    fresh.save(str(tmp_path / "db"))

    warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert _answers(warm, queries) == want
    s = warm.stats
    assert s["filter_packed_rows"] == 0, "warm open re-packed stage 0"
    assert s.get("index_signatures_built", 0) == 0, "warm open re-sketched"
    assert s["open_wall_s"] > 0 and s["ingest_wall_s"] == 0
    assert len(warm) == len(fresh)


def test_fresh_store_stats_split_ingest_wall(tmp_path):
    """PR satellite: ``ingest_wall_s`` covers the split ``vocab_wall_s``
    + ``pack_wall_s`` on fresh ingest; ``open_wall_s`` stays zero."""
    s = ged.GraphStore(_corpus(1, 8), **STORE_OPTS).stats
    assert s["ingest_wall_s"] >= s["vocab_wall_s"] + s["pack_wall_s"] > 0
    assert s["open_wall_s"] == 0


def test_open_missing_or_empty_dir_raises(tmp_path):
    with pytest.raises(StoreIOError):
        ged.GraphStore.open(str(tmp_path / "nope"))
    (tmp_path / "empty").mkdir()
    with pytest.raises(StoreIOError):
        ged.GraphStore.open(str(tmp_path / "empty"))


# ------------------------------------------------- corruption recovery

def test_truncated_derived_segment_rebuilds(tmp_path):
    """A truncated derived segment (digests) recovers by re-deriving from
    the persisted graphs — warned, never a wrong answer."""
    corpus = _corpus(2, 10)
    fresh = ged.GraphStore(corpus, **STORE_OPTS)
    want = _answers(fresh, [corpus[0], corpus[5]])
    fresh.save(str(tmp_path / "db"))

    path = _segment(str(tmp_path / "db"), "digests.exact.npy")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.warns(RuntimeWarning, match="re-deriving"):
        warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert _answers(warm, [corpus[0], corpus[5]]) == want


def test_bitflipped_derived_segment_rebuilds(tmp_path):
    """A checksum mismatch in the sketch matrix is caught before any
    query runs; the store re-derives and still answers correctly."""
    corpus = _corpus(3, 10)
    fresh = ged.GraphStore(corpus, **STORE_OPTS)
    want = _answers(fresh, [corpus[0]])
    fresh.save(str(tmp_path / "db"))

    path = _segment(str(tmp_path / "db"), "index.sigs.npy")
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) - 4)
        f.write(b"\xff\xff\xff\xff")
    with pytest.warns(RuntimeWarning, match="re-deriving"):
        warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert _answers(warm, [corpus[0]]) == want
    assert warm.stats["index_signatures_built"] > 0    # rebuild really ran


def test_primary_corruption_raises_then_heals_with_graphs(tmp_path):
    """Primary segments are not derivable: corruption raises.  With the
    original graphs supplied, ``open`` warns, re-ingests, and re-saves —
    after which a plain open works again."""
    corpus = _corpus(4, 9)
    fresh = ged.GraphStore(corpus, **STORE_OPTS)
    want = _answers(fresh, [corpus[0]])
    fresh.save(str(tmp_path / "db"))

    path = _segment(str(tmp_path / "db"), "graphs.vlabels.npy")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(StoreIOError):
        ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    with pytest.warns(RuntimeWarning, match="re-ingesting"):
        healed = ged.GraphStore.open(str(tmp_path / "db"), graphs=corpus,
                                     **STORE_OPTS)
    assert _answers(healed, [corpus[0]]) == want
    warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert _answers(warm, [corpus[0]]) == want


def test_truncated_manifest_raises_then_heals(tmp_path):
    corpus = _corpus(5, 8)
    ged.GraphStore(corpus, **STORE_OPTS).save(str(tmp_path / "db"))
    mpath = tmp_path / "db" / MANIFEST_NAME
    mpath.write_text(mpath.read_text()[:40])
    with pytest.raises(StoreIOError):
        ged.GraphStore.open(str(tmp_path / "db"))
    with pytest.warns(RuntimeWarning, match="re-ingesting"):
        healed = ged.GraphStore.open(str(tmp_path / "db"), graphs=corpus,
                                     **STORE_OPTS)
    assert len(healed) == len(corpus)


def test_schema_version_bump_raises_then_heals(tmp_path):
    """A future-version manifest is *not* bit rot: it raises the typed
    SchemaVersionError, and heals the same way corruption does."""
    corpus = _corpus(6, 8)
    ged.GraphStore(corpus, **STORE_OPTS).save(str(tmp_path / "db"))
    mpath = tmp_path / "db" / MANIFEST_NAME
    raw = json.loads(mpath.read_text())
    raw["version"] += 1
    mpath.write_text(json.dumps(raw))
    with pytest.raises(SchemaVersionError):
        ged.GraphStore.open(str(tmp_path / "db"))
    with pytest.warns(RuntimeWarning, match="re-ingesting"):
        healed = ged.GraphStore.open(str(tmp_path / "db"), graphs=corpus,
                                     **STORE_OPTS)
    assert len(healed) == len(corpus)


def test_crash_mid_save_keeps_previous_generation(tmp_path):
    """A simulated crash mid-save (orphan tmp dir, manifest untouched)
    leaves the previous generation fully readable."""
    corpus = _corpus(7, 8)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    want = _answers(store, [corpus[0]])
    store.save(str(tmp_path / "db"))
    crash = tmp_path / "db" / "seg-00000002.tmp-crashed"
    crash.mkdir()
    (crash / "graphs.ids.npy").write_bytes(b"partial write")
    warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert _answers(warm, [corpus[0]]) == want


# ------------------------------------------------ journal & compaction

def test_journal_roundtrip_add_remove_compact(tmp_path):
    """add/remove journal entries replay on open to the mutated state;
    ``compact`` folds them into a fresh generation."""
    corpus = _corpus(8, 10)
    rng = np.random.default_rng(80)
    extra = [perturb(rng, corpus[2], 1, n_vlabels=3, n_elabels=2),
             random_graph(rng, 5, density=0.4, n_vlabels=3, n_elabels=2)]
    store = ged.GraphStore(corpus, **STORE_OPTS)
    store.save(str(tmp_path / "db"))
    new_ids = store.add(extra)
    assert new_ids == [len(corpus), len(corpus) + 1]
    store.remove([1, new_ids[0]])
    assert store.stats["journal_pending"] == 2
    want = _answers(store, [corpus[0], extra[0]])

    warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert len(warm) == len(store)
    assert _answers(warm, [corpus[0], extra[0]]) == want

    store.compact()
    assert store.stats["journal_pending"] == 0
    assert store.stats["compactions"] == 1
    assert _answers(store, [corpus[0], extra[0]]) == want
    warm2 = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert _answers(warm2, [corpus[0], extra[0]]) == want
    # removed ids never come back as hits
    hit_ids = {h.graph_id for h in warm2.range_search(corpus[1], 100.0)}
    assert 1 not in hit_ids and new_ids[0] not in hit_ids


def test_interrupted_journal_append_is_dropped(tmp_path):
    """A torn *final* journal entry is an interrupted append: dropped
    with a warning.  Earlier state is untouched."""
    corpus = _corpus(9, 8)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    store.save(str(tmp_path / "db"))
    rng = np.random.default_rng(90)
    store.add([random_graph(rng, 4, density=0.4, n_vlabels=3,
                            n_elabels=2)])
    jdir = tmp_path / "db" / "journal"
    entries = sorted(p for p in os.listdir(jdir) if p.endswith(".json"))
    last = jdir / entries[-1]
    last.write_text(last.read_text()[:25])
    with pytest.warns(RuntimeWarning):
        warm = ged.GraphStore.open(str(tmp_path / "db"), **STORE_OPTS)
    assert len(warm) == len(corpus)          # the torn add never lands


def test_auto_compaction_threshold(tmp_path):
    corpus = _corpus(10, 6)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    store.save(str(tmp_path / "db"))
    store.compact_every = 3
    rng = np.random.default_rng(100)
    for i in range(3):
        store.add([random_graph(rng, 4, density=0.4, n_vlabels=3,
                                n_elabels=2)])
    assert store.stats["compactions"] == 1
    assert store.stats["journal_pending"] == 0


def test_remove_validation_and_id_stability(tmp_path):
    corpus = _corpus(11, 8)
    store = ged.GraphStore(corpus, **STORE_OPTS)
    store.remove([2])
    with pytest.raises(KeyError):
        store.remove([2])                    # already tombstoned
    with pytest.raises(KeyError):
        store.remove([10 ** 6])              # never existed
    with pytest.raises(KeyError):
        store.verify_members(corpus[0], [2], [1.0])
    rng = np.random.default_rng(110)
    new = store.add([random_graph(rng, 4, density=0.4, n_vlabels=3,
                                  n_elabels=2)])
    assert new == [len(corpus)]              # ids are never reused


# ----------------------------------------------------- shared cache

def _outcome(certified=True, ged_val=2.0):
    return GedOutcome(ged=ged_val, similar=True, certified=certified,
                      lower_bound=ged_val, upper_bound=ged_val,
                      mapping=None, backend="jax", wall_s=0.01, tau=4.0)


def _key(dq=b"q" * 16, dg=b"g" * 16, tau=4.0):
    return ("exact", dq, dg, True, tau, None, "jax")


def test_shared_cache_certified_only(tmp_path):
    cache = SharedResultCache(str(tmp_path))
    assert not cache.put(_key(), _outcome(certified=False))
    assert cache.get(_key()) is None and cache.misses == 1
    assert cache.put(_key(), _outcome())
    hit = cache.get(_key())
    assert (hit.ged, hit.certified, hit.backend) == (2.0, True,
                                                    "shared-cache")
    assert hit.mapping is None               # mappings are never stored


def test_shared_cache_orientation_symmetry(tmp_path):
    cache = SharedResultCache(str(tmp_path))
    cache.put(_key(b"a" * 16, b"b" * 16), _outcome())
    assert cache.get(_key(b"b" * 16, b"a" * 16)).ged == 2.0
    # but tau is part of the key: a different threshold misses
    assert cache.get(_key(b"a" * 16, b"b" * 16, tau=5.0)) is None


def test_shared_cache_corrupt_entry_is_a_miss(tmp_path):
    cache = SharedResultCache(str(tmp_path))
    cache.put(_key(), _outcome())
    path = cache._path(_key())
    with open(path, "w") as f:
        f.write('{"torn": ')
    assert cache.get(_key()) is None
    assert cache.misses == 1


def test_shared_cache_lru_eviction(tmp_path):
    cache = SharedResultCache(str(tmp_path), max_entries=2, sweep_every=1)
    keys = [_key(bytes([i]) * 16, bytes([i + 100]) * 16) for i in range(4)]
    for k in keys:
        cache.put(k, _outcome())
    assert cache.entries() == 2
    assert cache.evictions == 2
    assert cache.get(keys[-1]) is not None   # newest survives


def test_engine_shared_cache_across_instances(tmp_path):
    """Two engine instances (stand-ins for two processes) share certified
    verdicts through the directory; stats surface the tier."""
    rng = np.random.default_rng(12)
    q = random_graph(rng, 5, density=0.4, n_vlabels=3, n_elabels=2)
    g = perturb(rng, q, 2, n_vlabels=3, n_elabels=2)

    eng1 = ged.GedEngine("exact", shared_cache_dir=str(tmp_path))
    out1 = eng1.verify([(q, g)], [4.0])[0]
    assert out1.certified
    assert eng1.stats["shared_cache_entries"] >= 1

    eng2 = ged.GedEngine("exact", shared_cache_dir=str(tmp_path))
    out2 = eng2.verify([(q, g)], [4.0])[0]
    assert out2.backend == "shared-cache"
    assert (out2.ged, out2.similar) == (out1.ged, out1.similar)
    assert eng2.stats["shared_cache_hits"] == 1
    # promotion: the in-memory tier answers the repeat
    eng2.verify([(q, g)], [4.0])
    assert eng2.stats["shared_cache_hits"] == 1
    assert eng2.stats["result_cache_hits"] >= 1


def test_engine_shared_cache_env_var(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_GED_SHARED_CACHE_DIR", str(tmp_path))
    eng = ged.GedEngine("exact")
    assert eng.shared_cache_dir == str(tmp_path)
    monkeypatch.delenv("REPRO_GED_SHARED_CACHE_DIR")
    assert ged.GedEngine("exact").shared_cache_dir is None


CONTENTION_SCRIPT = textwrap.dedent("""
    import sys; sys.path.insert(0, %r)
    import multiprocessing as mp

    def worker(args):
        directory, wid = args
        from repro.ged.results import GedOutcome
        from repro.store_io import SharedResultCache
        cache = SharedResultCache(directory, max_entries=64,
                                  sweep_every=4)
        hits = 0
        for i in range(40):
            key = ("exact", bytes([i %% 8]) * 16, bytes([i %% 8 + 8]) * 16,
                   True, 4.0, None, "jax")
            out = GedOutcome(ged=float(i %% 8), similar=True,
                             certified=True, lower_bound=float(i %% 8),
                             upper_bound=float(i %% 8), mapping=None,
                             backend="jax", wall_s=0.0, tau=4.0)
            cache.put(key, out)
            got = cache.get(key)
            if got is not None:
                assert got.ged == float(i %% 8), (wid, i, got.ged)
                hits += 1
        return hits

    if __name__ == "__main__":
        directory = sys.argv[1]
        with mp.Pool(2) as pool:
            hits = pool.map(worker, [(directory, w) for w in range(2)])
        assert all(h > 0 for h in hits), hits
        print("OK", hits)
""")


def test_shared_cache_two_process_contention(tmp_path):
    """Two real processes hammer one cache directory: every read sees a
    complete entry with the right scalars (atomic writes + lock), never
    a torn one."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run(
        [sys.executable, "-c", CONTENTION_SCRIPT % src, str(tmp_path)],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ------------------------------------------------ services & sharding

def test_similarity_service_warm_start(tmp_path):
    from repro.serving.ged_service import GedSimilarityService
    corpus = _corpus(13, 8)
    svc = GedSimilarityService(corpus, batch_size=8, pool=256, expand=4,
                               max_iters=256)
    want = _hits(svc.range_search(corpus[0], 3.0))
    svc.store.save(str(tmp_path / "db"))
    warm = GedSimilarityService(store_dir=str(tmp_path / "db"),
                                batch_size=8, pool=256, expand=4,
                                max_iters=256)
    assert _hits(warm.range_search(corpus[0], 3.0)) == want
    with pytest.raises(TypeError):
        GedSimilarityService()


def test_verification_service_warm_start(tmp_path):
    from repro.serving.ged_service import (GedRequest,
                                           GedVerificationService)
    corpus = _corpus(14, 6)
    svc = GedVerificationService(batch_size=8)
    svc.register_corpus(corpus, filter_pool=16)
    svc.store.save(str(tmp_path / "db"))

    warm = GedVerificationService(batch_size=8)
    with pytest.raises(TypeError):
        warm.register_corpus(store_dir=str(tmp_path / "db"), pool=512)
    warm.register_corpus(store_dir=str(tmp_path / "db"))
    reqs = [GedRequest(corpus[0], corpus[1], tau=3.0)]
    a = [(o.similar, o.certified) for o in svc.verify(reqs)]
    b = [(o.similar, o.certified) for o in warm.verify(reqs)]
    assert a == b
    with pytest.raises(TypeError):
        GedVerificationService().register_corpus()


SHARDED_OPEN_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, numpy as np
    from repro import ged
    from repro.data.graphs import perturb, random_graph
    from repro.ged.exec import ShardedExecutor

    assert jax.device_count() == 8
    rng = np.random.default_rng(15)
    corpus = [random_graph(rng, int(rng.integers(3, 8)), density=0.4,
                           n_vlabels=3, n_elabels=2) for _ in range(13)]
    corpus.append(perturb(rng, corpus[0], 1, n_vlabels=3, n_elabels=2))
    opts = dict(pool=256, expand=4, max_iters=256, batch_size=8)

    fresh = ged.GraphStore(corpus, **opts)
    db = tempfile.mkdtemp()
    fresh.save(db)

    mesh = jax.make_mesh((8,), ("data",))
    warm = ged.GraphStore.open(db, mesh=mesh, **opts)
    assert isinstance(warm.executor, ShardedExecutor)
    # mmap-restored feature buckets re-pad to the shard multiple
    assert all(b.features.batch %% 8 == 0 for b in warm._index.buckets)
    assert warm.stats["filter_packed_rows"] == 0

    q = corpus[0]
    for tau in (1.0, 3.0):
        a = [(h.graph_id, h.ged, h.similar, h.certified)
             for h in fresh.range_search(q, tau)]
        b = [(h.graph_id, h.ged, h.similar, h.certified)
             for h in warm.range_search(q, tau)]
        assert a == b, (tau, a, b)
    assert [h.graph_id for h in warm.top_k(q, 4)] == \\
        [h.graph_id for h in fresh.top_k(q, 4)]
    print("OK")
""")


@pytest.mark.slow
def test_sharded_warm_open_parity_on_8_devices():
    """A store saved on one device and mmap-opened onto a real 8-device
    mesh answers exactly like the fresh single-device store."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SHARDED_OPEN_SCRIPT % src],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
