#!/usr/bin/env python
"""Benchmark-trajectory diff for ``results/bench/BENCH_engine.json``.

The benchmark record is committed per PR (``{section: rows}`` via
``benchmarks.common.record_section``); this tool compares the current file
against the previously *committed* version and prints a per-metric
regression table, so CI surfaces perf drift without blocking on it
(timings on shared runners are noisy — the table is a warning, not a
gate).

Usage::

    python tools/bench_diff.py                       # vs previous commit
    python tools/bench_diff.py --base HEAD~3         # vs an explicit ref
    python tools/bench_diff.py --strict              # exit 1 on regression

The baseline is ``git show <ref>:<file>``; ``--base`` defaults to the
last commit that touched the file *before* the current one, i.e. the
previous benchmark run that was checked in.  Throughput metrics
(``*_per_s``) count as regressed when they drop more than ``--threshold``
(default 20%); selectivity metrics (``examined_frac`` — the fraction of
the corpus the candidate index leaves for the linear stages) are
smaller-is-better and count as regressed when they *rise* by more than
the threshold; everything else is informational.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

DEFAULT_FILE = "results/bench/BENCH_engine.json"
# Row fields used to label a row inside a section (first match wins).
# "case" leads: the kernel_hotpath section pre-composes its identity
# (kernel + shape) into one field so same-kernel rows at different N
# stay distinct across PRs; "run" labels the compile_cache cold/warm rows.
ROW_KEYS = ("case", "run", "backend", "mode", "strategy", "bound", "tau",
            "corpus")


def row_label(row: Dict[str, Any], index: int) -> str:
    for key in ROW_KEYS:
        if key in row:
            return f"{key}={row[key]}"
    return f"row{index}"


def label_rows(rows: List[Dict]) -> Dict[str, Dict]:
    """Rows keyed by label, duplicates disambiguated by occurrence.

    Two rows sharing their identifying field (e.g. the same backend at
    two taus) must both survive into the diff, so repeats get a ``#n``
    suffix instead of overwriting each other.
    """
    out: Dict[str, Dict] = {}
    seen: Dict[str, int] = {}
    for i, row in enumerate(rows):
        label = row_label(row, i)
        n = seen.get(label, 0)
        seen[label] = n + 1
        out[label if n == 0 else f"{label}#{n}"] = row
    return out


def numeric_metrics(row: Dict[str, Any]) -> Dict[str, float]:
    return {k: float(v) for k, v in row.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}


def diff_sections(old: Dict[str, List[Dict]], new: Dict[str, List[Dict]]
                  ) -> List[Dict[str, Any]]:
    """Flat comparison rows: one per (section, row, shared numeric metric).

    Sections or rows present on only one side are reported with the other
    value ``None`` (new sections appear as additions, vanished ones as
    removals); ``delta_pct`` is filled only when both sides have a value.
    """
    out: List[Dict[str, Any]] = []
    for section in sorted(set(old) | set(new)):
        orows = label_rows(old.get(section, []))
        nrows = label_rows(new.get(section, []))
        for label in sorted(set(orows) | set(nrows)):
            om = numeric_metrics(orows.get(label, {}))
            nm = numeric_metrics(nrows.get(label, {}))
            for metric in sorted(set(om) | set(nm)):
                a, b = om.get(metric), nm.get(metric)
                delta = None
                if a is not None and b is not None and a != 0:
                    delta = 100.0 * (b - a) / abs(a)
                out.append({"section": section, "row": label,
                            "metric": metric, "old": a, "new": b,
                            "delta_pct": delta})
    return out


def regressions(rows: List[Dict[str, Any]], threshold_pct: float
                ) -> List[Dict[str, Any]]:
    """Metrics that moved the wrong way by more than ``threshold_pct``.

    Bigger-is-better: ``*_per_s`` covers the engine throughput sections;
    ``*_speedup`` covers the ``kernel_hotpath`` fused-vs-unfused and
    merge-vs-argsort ratios, so a kernel that silently loses its edge
    shows up the same way a throughput drop does.  Smaller-is-better:
    ``examined_frac`` (the ``candidate_index`` section's stage −1
    selectivity — corpus fraction surviving into the linear stages), so
    an index that silently stops pruning also surfaces here.
    """
    def bad(r: Dict[str, Any]) -> bool:
        if r["delta_pct"] is None:
            return False
        m = r["metric"]
        if m.endswith("_per_s") or m.endswith("_speedup"):
            return r["delta_pct"] < -threshold_pct
        if m.endswith("examined_frac"):
            return r["delta_pct"] > threshold_pct
        return False

    return [r for r in rows if bad(r)]


def load_baseline(path: str, base: Optional[str]) -> Tuple[Optional[Dict],
                                                           str]:
    """The committed baseline JSON for ``path`` (and the ref used)."""
    if base is None:
        log = subprocess.run(
            ["git", "log", "--format=%H", "-2", "HEAD", "--", path],
            capture_output=True, text=True)
        commits = log.stdout.split()
        if log.returncode != 0 or not commits:
            return None, "(no git history)"
        # if the working tree still matches HEAD's copy, HEAD *is* the
        # baseline of interest only when an older run exists; prefer the
        # previous touching commit, falling back to HEAD.
        base = commits[1] if len(commits) > 1 else commits[0]
    show = subprocess.run(["git", "show", f"{base}:{path}"],
                          capture_output=True, text=True)
    if show.returncode != 0:
        return None, base
    try:
        data = json.loads(show.stdout)
    except ValueError:
        return None, base
    return (data if isinstance(data, dict) else None), base


def print_diff(rows: List[Dict[str, Any]]) -> None:
    def fmt(v) -> str:
        if v is None:
            return "-"
        return f"{v:.5g}" if isinstance(v, float) else str(v)

    header = f"{'section':<22} {'row':<22} {'metric':<22} " \
             f"{'old':>12} {'new':>12} {'delta%':>8}"
    print(header)
    print("-" * len(header))
    for r in rows:
        print(f"{r['section']:<22} {r['row']:<22} {r['metric']:<22} "
              f"{fmt(r['old']):>12} {fmt(r['new']):>12} "
              f"{fmt(r['delta_pct']):>8}")


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("file", nargs="?", default=DEFAULT_FILE)
    ap.add_argument("--base", default=None,
                    help="git ref for the baseline (default: previous "
                         "commit touching the file)")
    ap.add_argument("--threshold", type=float, default=20.0,
                    help="throughput-drop warn threshold, percent")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when a throughput regression "
                         "exceeds the threshold")
    args = ap.parse_args(argv)

    path = Path(args.file)
    if not path.exists():
        print(f"bench-diff: {path} not found", file=sys.stderr)
        return 0 if not args.strict else 2
    try:
        new = json.loads(path.read_text())
    except ValueError as e:
        print(f"bench-diff: unreadable {path}: {e}", file=sys.stderr)
        return 0 if not args.strict else 2
    if not isinstance(new, dict):
        print(f"bench-diff: {path} is not a section map", file=sys.stderr)
        return 0 if not args.strict else 2

    old, ref = load_baseline(str(path), args.base)
    if old is None:
        print(f"bench-diff: no baseline at {ref}; nothing to compare")
        return 0
    rows = diff_sections(old, new)
    print(f"bench-diff: {path} vs {ref}")
    print_diff(rows)
    regs = regressions(rows, args.threshold)
    if regs:
        print(f"\nWARNING: {len(regs)} throughput regression(s) beyond "
              f"{args.threshold:.0f}% (non-blocking; timings are noisy):")
        for r in regs:
            print(f"  {r['section']}/{r['row']}: {r['metric']} "
                  f"{r['old']:.4g} -> {r['new']:.4g} "
                  f"({r['delta_pct']:+.1f}%)")
        if args.strict:
            return 1
    else:
        print("\nno throughput regressions beyond threshold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
