#!/usr/bin/env python
"""Offline markdown link checker for the docs suite.

Validates every ``[text](target)`` link in the given markdown files /
directories:

* relative file targets must exist on disk (resolved against the file
  containing the link);
* ``#anchor`` fragments must match a heading in the target markdown
  file (GitHub slug rules: lowercase, punctuation stripped, spaces to
  hyphens);
* external ``http(s)``/``mailto`` targets are syntax-checked only — CI
  stays deterministic with no network.

Usage::

    python tools/check_links.py README.md docs

Exits non-zero (listing every broken link) when anything dangles, so
the CI job — and the tier-1 test that wraps these functions — fails
instead of letting the docs rot silently.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

# [text](target) — target up to the first closing paren / whitespace;
# images (![alt](src)) match too, which is what we want.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading line."""
    text = re.sub(r"[`*~]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(path: Path) -> set:
    slugs = set()
    seen: dict = {}
    in_code = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        m = HEADING.match(line)
        if m:
            s = slugify(m.group(1))
            n = seen.get(s, 0)
            seen[s] = n + 1
            # GitHub disambiguates repeated headings with -1, -2, ...
            slugs.add(s if n == 0 else f"{s}-{n}")
    return slugs


def markdown_files(args: List[str]) -> List[Path]:
    files: List[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        else:
            files.append(p)
    return files


def check_file(path: Path) -> List[str]:
    """Broken-link descriptions for one markdown file (empty = clean)."""
    errors = []
    text = path.read_text(encoding="utf-8")
    # ignore fenced blocks and inline code spans: example syntax, not links
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    text = re.sub(r"`[^`\n]*`", "", text)
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, anchor = target.partition("#")
        dest = path if not base else (path.parent / base).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if slugify(anchor) not in heading_slugs(dest):
                errors.append(f"{path}: dangling anchor -> {target}")
    return errors


def main(argv: List[str]) -> int:
    files = markdown_files(argv or ["README.md", "docs"])
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 2
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
